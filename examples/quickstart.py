"""Quickstart: the AceleradorSNN stack in ~40 lines.

DVS events -> voxel grid -> spiking NPU (detection + control vector) ->
Cognitive ISP -> corrected RGB.

  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro.configs.registry import reduced_snn
from repro.core.cognitive import cognitive_step
from repro.core.encoding import voxel_batch
from repro.core.npu import init_npu
from repro.core.yolo import decode_boxes
from repro.data.synthetic import make_scene_batch


def main():
    cfg = reduced_snn("spiking_yolo")
    rng = jax.random.PRNGKey(0)

    # a batch of synthetic GEN1-like scenes (events + Bayer frame + GT)
    scene = make_scene_batch(rng, batch=4, height=cfg.height,
                             width=cfg.width, time_steps=cfg.time_steps,
                             lighting=0.6, wb_drift=(1.4, 0.8))
    vox = voxel_batch(scene.events, time_steps=cfg.time_steps,
                      height=cfg.height, width=cfg.width)
    print(f"voxel grid: {vox.shape}  (T, B, H, W, polarity)")
    print(f"event rate: {float(jnp.mean(vox > 0)):.3f}")

    # NPU + closed cognitive loop in one step
    params = init_npu(jax.random.PRNGKey(1), cfg)
    out = cognitive_step(params, vox, scene.bayer, cfg)

    boxes, scores, classes = decode_boxes(out.npu.raw_pred, cfg)
    k = int(jnp.argmax(scores[0]))
    print(f"detections: {boxes.shape[1]} candidates/image; "
          f"top box={boxes[0, k]} score={float(scores[0, k]):.3f}")
    print(f"network sparsity: {float(out.npu.sparsity):.3f} "
          f"(paper: MobileNet 48.08%)")
    print(f"NPU->ISP control vector[0]: {out.npu.control[0]}")
    print(f"ISP output: {out.rgb.shape} "
          f"PSNR vs clean: "
          f"{-10 * jnp.log10(jnp.mean((out.rgb - scene.clean_rgb) ** 2)):.2f} dB")


if __name__ == "__main__":
    main()
