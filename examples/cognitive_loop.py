"""The closed cognitive loop (paper §VI): the NPU watches the DVS stream
and reconfigures the ISP on the fly.  We train the control head
end-to-end (differentiable ISP — something the FPGA cannot do) on scenes
with photometric drift, then show the NPU-driven ISP beating the static
ISP as lighting changes.

For the streaming/slot-based deployment of this loop (and reconfigured
stage orderings via the ISP stage registry) see cognitive_stream.py.

  PYTHONPATH=src python examples/cognitive_loop.py [--steps 80]
"""
import argparse

import jax
import jax.numpy as jnp

from repro.configs.registry import reduced_snn
from repro.core.cognitive import cognitive_step
from repro.core.encoding import voxel_batch
from repro.core.npu import init_npu
from repro.core.train import init_snn_state, make_snn_train_step
from repro.data.synthetic import make_scene_batch
from repro.isp.pipeline import default_params, isp_pipeline_batch
from repro.optim.adamw import AdamWConfig


def psnr(a, b):
    return float(-10 * jnp.log10(jnp.maximum(
        jnp.mean((a - b) ** 2), 1e-9)))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=80)
    args = ap.parse_args()

    cfg = reduced_snn("spiking_yolo")
    opt = AdamWConfig(lr=2e-3, weight_decay=1e-4)
    state = init_snn_state(init_npu(jax.random.PRNGKey(0), cfg), opt)
    step = jax.jit(make_snn_train_step(cfg, opt, mode="cognitive"))

    def drift_scene(i, lighting, wb):
        return make_scene_batch(jax.random.PRNGKey(i), batch=4,
                                height=cfg.height, width=cfg.width,
                                time_steps=cfg.time_steps,
                                lighting=lighting, wb_drift=wb)

    print("training the cognitive loop on drifting scenes...")
    for i in range(args.steps):
        # lighting & colour drift vary across the stream
        light = 0.4 + 0.4 * ((i * 37) % 10) / 10
        wb = (1.0 + 0.5 * ((i * 13) % 7) / 7, 0.7 + 0.3 * ((i * 7) % 5) / 5)
        state, m = step(state, drift_scene(i, light, wb))
        if i % 20 == 0:
            print(f"  step {i}: loss={float(m['loss']):.3f} "
                  f"recon={float(m['recon']):.4f}")

    print("\nevaluation under three lighting conditions:")
    for light, wb, label in [(0.45, (1.5, 0.7), "dim, warm-shifted"),
                             (0.8, (0.8, 1.3), "normal, cool-shifted"),
                             (1.0, (1.0, 1.0), "nominal")]:
        scene = drift_scene(1000, light, wb)
        vox = voxel_batch(scene.events, time_steps=cfg.time_steps,
                          height=cfg.height, width=cfg.width)
        out = cognitive_step(state.params, vox, scene.bayer, cfg)
        static = isp_pipeline_batch(scene.bayer, default_params())
        print(f"  {label:24s} PSNR: static ISP "
              f"{psnr(static, scene.clean_rgb):5.2f} dB | cognitive "
              f"{psnr(out.rgb, scene.clean_rgb):5.2f} dB")
        p = jax.tree_util.tree_map(lambda x: float(x[0]), out.isp_params)
        print(f"    NPU chose: exposure={p.exposure_gain:.2f} "
              f"wb_r={p.wb_bias_r:.2f} wb_b={p.wb_bias_b:.2f} "
              f"gamma={p.gamma:.2f} nlm={p.nlm_strength:.2f}")


if __name__ == "__main__":
    main()
